"""MorphoSys M1 datapath emulation: RC array, frame buffer, context memory.

Functional semantics follow sections 2-3 and 5 of the paper:

  * The RC array is an 8x8 grid of 16-bit ALU/multiplier cells.  Every cell
    in a column (column-broadcast mode) or row (row-broadcast mode) executes
    the *same* context word -- SIMD by configuration.
  * Each cell has a small register file; we model the output register and one
    accumulator register (enough for the paper's routines, which use the
    multiply-accumulate path for the matrix mapping of section 5.3).
  * The frame buffer has two *sets* (0/1) for compute/DMA overlap and two
    *banks* (A/B) per set so a double-bank broadcast (``dbcdc``) can feed two
    operand streams in one cycle.
  * Arithmetic is 16-bit signed wrap-around (the current M1 prototype's
    ALU-Multiplier "operates only on signed numbers", section 3).

Context-word encoding: the paper publishes two words -- ``0x0000F400`` for
``Out = A + B`` (Table 1) and ``0x00009005`` for ``Out = c x A`` with
``c = 5`` (Table 2).  We define a decode consistent with both:

  bits [15:12]  major opcode: 0xF = two-operand ALU, 0x9 = constant multiply
  bits [11:8]   ALU subfunction for 0xF: 0x4 add, 0x5 sub, 0x6 mul
  bits [7:0]    immediate (constant) operand for 0x9 / 0xA
  0xA           constant multiply-accumulate (CMUL+acc, section 5.3 mapping)
  0xB           constant add (vector-scalar add; section 5.2 "or any other
                operation (arithmetic or logical)")
"""
from __future__ import annotations

import dataclasses
import numpy as np

N = 8  # RC array is 8x8


# ---------------------------------------------------------------------------
# context words
# ---------------------------------------------------------------------------

OP_ADD_AB = "add_ab"
OP_SUB_AB = "sub_ab"
OP_MUL_AB = "mul_ab"
OP_CMUL = "cmul"        # out = imm * a
OP_CMAC = "cmac"        # acc += imm * a   (matrix mapping, section 5.3)
OP_CADD = "cadd"        # out = a + imm

_MAJOR = {OP_ADD_AB: 0xF, OP_SUB_AB: 0xF, OP_MUL_AB: 0xF,
          OP_CMUL: 0x9, OP_CMAC: 0xA, OP_CADD: 0xB}
_SUB = {OP_ADD_AB: 0x4, OP_SUB_AB: 0x5, OP_MUL_AB: 0x6}


def encode_context(op: str, imm: int = 0) -> int:
    """Encode an RC context word; 0x0000F400 == add, 0x00009005 == cmul(5)."""
    major = _MAJOR[op]
    if major == 0xF:
        return (major << 12) | (_SUB[op] << 8)
    return (major << 12) | (int(imm) & 0xFF)


def decode_context(word: int) -> tuple[str, int]:
    major = (word >> 12) & 0xF
    if major == 0xF:
        sub = (word >> 8) & 0xF
        for op, s in _SUB.items():
            if s == sub:
                return op, 0
        raise ValueError(f"bad ALU subfunction {sub:#x} in context {word:#010x}")
    imm = word & 0xFF
    if imm >= 0x80:          # immediates are 8-bit two's-complement
        imm -= 0x100
    if major == 0x9:
        return OP_CMUL, imm
    if major == 0xA:
        return OP_CMAC, imm
    if major == 0xB:
        return OP_CADD, imm
    raise ValueError(f"bad context word {word:#010x}")


# ---------------------------------------------------------------------------
# storage
# ---------------------------------------------------------------------------

class FrameBuffer:
    """Two sets x two banks of 16-bit words (set 1 mirrors set 0's layout).

    The double-set organisation is what lets DMA refill proceed while the RC
    array computes (paper section 2) -- the property our Pallas kernels
    reproduce as double-buffered HBM->VMEM pipelines.
    """

    WORDS_PER_BANK = 1024

    def __init__(self) -> None:
        # [set][bank] -> int16 array
        self.mem = np.zeros((2, 2, self.WORDS_PER_BANK), dtype=np.int16)

    def write(self, fb_set: int, bank: int, addr: int, data: np.ndarray) -> None:
        data = np.asarray(data, dtype=np.int16)
        self.mem[fb_set, bank, addr:addr + data.size] = data

    def read(self, fb_set: int, bank: int, addr: int, count: int) -> np.ndarray:
        return self.mem[fb_set, bank, addr:addr + count].copy()


class ContextMemory:
    """Column block / row block of context words (two planes each)."""

    WORDS = 32

    def __init__(self) -> None:
        self.col = np.zeros((2, self.WORDS), dtype=np.uint32)   # [plane, word]
        self.row = np.zeros((2, self.WORDS), dtype=np.uint32)

    def load(self, block: str, plane: int, start: int, words: np.ndarray) -> None:
        words = np.asarray(words, dtype=np.uint32)
        target = self.col if block == "col" else self.row
        target[plane, start:start + words.size] = words

    def get(self, block: str, plane: int, word: int) -> int:
        target = self.col if block == "col" else self.row
        return int(target[plane, word])


@dataclasses.dataclass
class RCArray:
    """8x8 array of 16-bit cells.

    Each cell exposes its *output register*, which is also an ALU input port
    (section 3: "one port takes data from the output register") -- that port
    is what makes single-cycle multiply-accumulate possible, and is the
    accumulator of the section-5.3 matrix mapping.
    """

    out: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros((N, N), dtype=np.int16))

    # -- column broadcast ---------------------------------------------------
    def exec_column(self, col: int, ctx_word: int,
                    a: np.ndarray, b: np.ndarray | None) -> None:
        """All 8 cells of ``col`` execute ``ctx_word`` on operand streams.

        ``a``/``b`` are the 8-element operand vectors fed from the frame
        buffer banks (b is None for single-bank broadcasts)."""
        op, imm = decode_context(ctx_word)
        self.out[:, col] = _alu(op, imm, a, b, self.out[:, col])

    # -- row broadcast ------------------------------------------------------
    def exec_row_all(self, ctx_words: list[int], b_row: np.ndarray) -> None:
        """Row-context broadcast used by the section-5.3 matrix mapping.

        Row ``r``'s context word (typically CMAC with immediate A[r, k]) is
        executed by every cell in row ``r``; the operand stream ``b_row`` is
        the broadcast row of B (one element per column).
        """
        for r in range(N):
            op, imm = decode_context(ctx_words[r])
            self.out[r, :] = _alu(op, imm, b_row, None, self.out[r, :])

    def read_column(self, col: int) -> np.ndarray:
        return self.out[:, col].copy()


def _alu(op: str, imm: int, a: np.ndarray, b: np.ndarray | None,
         acc: np.ndarray) -> np.ndarray:
    """16-bit signed wrap-around ALU (numpy int16 arithmetic wraps)."""
    a16 = np.asarray(a, dtype=np.int16)
    with np.errstate(over="ignore"):
        if op == OP_ADD_AB:
            return (a16 + np.asarray(b, np.int16)).astype(np.int16)
        if op == OP_SUB_AB:
            return (a16 - np.asarray(b, np.int16)).astype(np.int16)
        if op == OP_MUL_AB:
            return (a16 * np.asarray(b, np.int16)).astype(np.int16)
        if op == OP_CMUL:
            return (np.int16(imm) * a16).astype(np.int16)
        if op == OP_CMAC:
            return (acc + np.int16(imm) * a16).astype(np.int16)
        if op == OP_CADD:
            return (a16 + np.int16(imm)).astype(np.int16)
    raise ValueError(op)
