"""Intel 80386/80486/Pentium instruction-level cycle models (Tables 3-5).

The paper compares the M1 mapping against hand-written x86 loops.  We
re-implement the per-instruction cycle accounting of Tables 3 and 4 exactly
and expose the published Table 5 constants for the two matrix algorithms
(for which the paper prints no instruction listing).

Known paper arithmetic slips (documented, reproduced in EXPERIMENTS.md):
Table 3's 64-element totals (769T on 80486, 1723T on 80386) are inconsistent
with Table 3's own per-instruction clocks, which give 706T and 1732T -- the
8-element totals (90T / 220T) and *all* Table 4 totals match our model
exactly.  ``translation_cycles`` returns the model value; the published
figure is available via PAPER_TABLE5.
"""
from __future__ import annotations

import dataclasses

CLOCK_MHZ = {"m1": 100.0, "80486": 100.0, "80386": 40.0, "pentium": 133.0}

# Table 3: MOV/MOV/ADD/MOV/INC/INC/INC/DEC body + JNZ (taken/fall-through)
_TRANSLATION = {
    "80486": dict(setup=4 * 1, body=8 * 1, jnz_taken=3, jnz_fall=1),
    "80386": dict(setup=4 * 2, body=4 + 4 + 2 + 2 + 2 + 2 + 2 + 2, jnz_taken=7, jnz_fall=3),
}

# Table 4: MOV/ADD/MOV/INC/INC/DEC body + JNZ
_SCALING = {
    "80486": dict(setup=4 * 1, body=6 * 1, jnz_taken=3, jnz_fall=1),
    "80386": dict(setup=4 * 2, body=4 + 2 + 2 + 2 + 2 + 2, jnz_taken=7, jnz_fall=3),
}


def _loop_cycles(params: dict, n: int) -> int:
    taken = params["body"] + params["jnz_taken"]
    last = params["body"] + params["jnz_fall"]
    return params["setup"] + (n - 1) * taken + last


def translation_cycles(cpu: str, n: int) -> int:
    """Table 3 model: vector-vector add loop of ``n`` elements."""
    return _loop_cycles(_TRANSLATION[cpu], n)


def scaling_cycles(cpu: str, n: int) -> int:
    """Table 4 model: vector-scalar loop of ``n`` elements."""
    return _loop_cycles(_SCALING[cpu], n)


def time_us(cpu: str, cycles: int) -> float:
    return cycles / CLOCK_MHZ[cpu]


@dataclasses.dataclass(frozen=True)
class Table5Row:
    algorithm: str
    system: str
    n_elements: int
    cycles: int
    speedup: float | None       # vs M1, as published (None for the M1 rows)
    total_time_us: float
    elements_per_cycle: float
    cycles_per_element: float


# Published Table 5, verbatim (the ground truth our reproduction validates
# against; speedups are published cycle ratios vs the M1 row above them).
PAPER_TABLE5: list[Table5Row] = [
    Table5Row("translation", "m1", 64, 96, None, 0.96, 0.667, 1.5),
    Table5Row("translation", "80486", 64, 769, 8.01, 7.69, 0.083, 12.0),
    Table5Row("translation", "80386", 64, 1723, 17.94, 43.075, 0.037, 26.9),
    Table5Row("scaling", "m1", 64, 55, None, 0.55, 1.16, 0.859),
    Table5Row("scaling", "80486", 64, 578, 10.51, 5.78, 0.047, 9.03),
    Table5Row("scaling", "80386", 64, 1348, 24.51, 33.7, 0.11, 21.2),
    Table5Row("rotation_matmul", "m1", 64, 256, None, 2.56, 0.25, 4.0),
    Table5Row("rotation_matmul", "pentium", 64, 10151, 39.65, 76.32, 0.006, 158.6),
    Table5Row("rotation_matmul", "80486", 64, 27038, 105.62, 270.38, 0.002, 422.4),
    Table5Row("composite_ii", "m1", 16, 70, None, 0.7, 0.228, 4.375),
    Table5Row("composite_ii", "pentium", 16, 1328, 18.97, 9.98, 0.012, 83.0),
    Table5Row("composite_ii", "80486", 16, 3354, 47.91, 33.54, 0.0047, 209.6),
    Table5Row("translation", "m1", 8, 21, None, 0.21, 0.38, 2.625),
    Table5Row("translation", "80486", 8, 90, 4.29, 0.9, 0.088, 11.36),
    Table5Row("translation", "80386", 8, 220, 10.48, 5.5, 0.036, 27.5),
    Table5Row("scaling", "m1", 8, 14, None, 0.14, 0.57, 1.75),
    Table5Row("scaling", "80486", 8, 74, 5.28, 0.74, 0.108, 9.25),
    Table5Row("scaling", "80386", 8, 172, 12.29, 4.3, 0.46, 21.7),
]


def paper_row(algorithm: str, system: str, n: int) -> Table5Row:
    for row in PAPER_TABLE5:
        if (row.algorithm, row.system, row.n_elements) == (algorithm, system, n):
            return row
    raise KeyError((algorithm, system, n))
