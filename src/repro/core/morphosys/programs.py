"""Program generators for the paper's routines + functional runners.

Layout convention (Figure 7/8 of the paper): a 64-element vector occupies the
RC array column-major -- column ``c`` holds elements ``8c .. 8c+7``; the
frame-buffer chunk feeding column ``c`` starts at element address ``8c``.

Cycle-count ground truth (paper Table 5):

  routine                     published   this reconstruction
  translation, 64 elements        96            96  (Table 1 listing, exact)
  translation,  8 elements        21            21  (fitted DMA model)
  scaling,     64 elements        55            55  (Table 2 listing, exact)
  scaling,      8 elements        14            14  (fitted DMA model)
  rotation (8x8 matmul)          256            90  (paper gives no listing;
                                                     see note below)
  composite II (2x2 x 2x8)        70            25  (same note)

Note: the paper publishes TinyRISC listings only for translation and scaling.
For the section-5.3 matrix mapping it reports 256 / 70 cycles without a
listing.  Our straight-line reconstruction (context stream for A rows +
row-broadcast of B + MAC) is substantially faster because it overlaps context
loads with only 3 wait slots and issues one MAC broadcast per cycle; the
paper's count implies ~4 cycles per output element (fully serialised context
reload + 2-cycle MAC).  ``benchmarks/paper_tables.py`` reports both numbers;
the published figures are used for the paper-fidelity speedup table and our
reconstruction is reported alongside as the (faster) emulator-validated
mapping.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.morphosys import rc_array as rc
from repro.core.morphosys.isa import I, Machine, Program, dma_wait

# main-memory addresses used by the paper's listings
ADDR_U = 0x10000
ADDR_V = 0x20000
ADDR_CTX = 0x30000
ADDR_OUT = 0x40000


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    cycles: int
    n_instructions: int
    machine: Machine


def _load_phase(addr_reg: int, hi: int, fb_set: int, bank: int, n: int) -> Program:
    """ldui + ldfb + DMA wait slots (the '...' gaps of Tables 1-2)."""
    return ([I("ldui", (addr_reg, hi)),
             I("ldfb", (addr_reg, fb_set, bank, 0, n))]
            + [I("nop")] * dma_wait(n))


def _context_phase(block: str = "col", count: int = 1) -> Program:
    """ldui + ldctxt + 3 wait slots (Table 1 lines 66-70 / Table 2 33-37)."""
    return ([I("ldui", (3, ADDR_CTX >> 16)),
             I("ldctxt", (3, block, 0, 0, count))]
            + [I("nop")] * 3)


# ---------------------------------------------------------------------------
# 5.1 vector-vector (translation)
# ---------------------------------------------------------------------------

def translation_program(n: int) -> Program:
    """Table 1 structure, generalised to any multiple of 8 up to 64."""
    assert n % rc.N == 0 and 0 < n <= rc.N * rc.N
    ncols = n // rc.N
    prog: Program = []
    prog += _load_phase(1, ADDR_U >> 16, 0, 0, n)          # vector U -> bank A
    prog += _load_phase(1, ADDR_V >> 16, 0, 1, n)          # vector V -> bank B
    prog += _context_phase()                                # Out = A + B
    for c in range(ncols):                                  # Table 1 71-86
        prog.append(I("ldli", (4, c)))
        prog.append(I("dbcdc", (c, 0, 0, 8 * c, 8 * c)))
    for c in range(ncols):                                  # Table 1 87-94
        prog.append(I("wfbi", (c, 1, 8 * c)))
    prog.append(I("ldui", (5, ADDR_OUT >> 16)))             # Table 1 95-96
    prog.append(I("stfb", (5, 1, 0, n)))
    return prog


def run_translation(u: np.ndarray, v: np.ndarray) -> RunResult:
    u = np.asarray(u, np.int16)
    v = np.asarray(v, np.int16)
    n = u.size
    m = Machine()
    m.poke_vector(ADDR_U, u)
    m.poke_vector(ADDR_V, v)
    m.poke_contexts(ADDR_CTX, [rc.encode_context(rc.OP_ADD_AB)])  # 0x0000F400
    prog = translation_program(n)
    cycles = m.run(prog)
    m.regs[5] = ADDR_OUT  # ldui loaded the high half; runner uses full addr
    out = m.peek_vector(ADDR_OUT, n)
    return RunResult(out, cycles, len(prog), m)


# ---------------------------------------------------------------------------
# 5.2 vector-scalar (scaling)
# ---------------------------------------------------------------------------

def scaling_program(n: int) -> Program:
    """Table 2 structure, generalised to any multiple of 8 up to 64."""
    assert n % rc.N == 0 and 0 < n <= rc.N * rc.N
    ncols = n // rc.N
    prog: Program = []
    prog += _load_phase(1, ADDR_U >> 16, 0, 0, n)           # vector U -> bank A
    prog += _context_phase()                                 # Out = c * A
    for c in range(ncols):                                   # Table 2 38-45
        prog.append(I("sbcb", (c, 0, 0, 0, 8 * c)))
    for c in range(ncols):                                   # Table 2 46-53
        prog.append(I("wfbi", (c, 1, 8 * c)))
    prog.append(I("ldui", (5, ADDR_OUT >> 16)))              # Table 2 54-55
    prog.append(I("stfb", (5, 1, 0, n)))
    return prog


def run_scaling(u: np.ndarray, c: int) -> RunResult:
    u = np.asarray(u, np.int16)
    n = u.size
    m = Machine()
    m.poke_vector(ADDR_U, u)
    m.poke_contexts(ADDR_CTX, [rc.encode_context(rc.OP_CMUL, c)])  # 0x00009005 for c=5
    prog = scaling_program(n)
    cycles = m.run(prog)
    out = m.peek_vector(ADDR_OUT, n)
    return RunResult(out, cycles, len(prog), m)


# ---------------------------------------------------------------------------
# 5.3 matrix-matrix (rotation / composite)
# ---------------------------------------------------------------------------

def matmul_program(rows: int, inner: int) -> Program:
    """Section 5.3 mapping: A rows streamed through row-context words (CMUL
    on the first k step, CMAC after), B rows broadcast to the array.

    ``rows`` = rows of A/C, ``inner`` = contraction length (rows of B).
    Full 8x8 rotation: (8, 8) -> 90 cycles (paper reports 256, no listing);
    composite II 2x2 @ 2x8: (2, 2) -> 25 cycles (paper reports 70)."""
    assert 0 < rows <= rc.N and 0 < inner <= rc.N
    prog: Program = []
    prog += _load_phase(1, ADDR_V >> 16, 0, 0, rc.N * inner)  # B row-major -> bank A
    for k in range(inner):                                    # stream A column k
        prog += ([I("ldui", (3, (ADDR_CTX + rc.N * k) >> 16)),
                  I("ldli", (3, (ADDR_CTX + rc.N * k) & 0xFFFF)),
                  I("ldctxt", (3, "row", 0, 0, rc.N))]
                 + [I("nop")] * 2
                 + [I("sbrb", (0, 0, 8 * k))])                # broadcast B[k, :]
    for r in range(rows):
        prog.append(I("wfbr", (r, 1, 8 * r)))
    prog.append(I("ldui", (5, ADDR_OUT >> 16)))
    prog.append(I("stfb", (5, 1, 0, rc.N * rows)))
    return prog


def run_matmul(a: np.ndarray, b: np.ndarray) -> RunResult:
    """C = A @ B with A (rows x inner, |A_ij| < 128 for the 8-bit context
    immediate field) and B (inner x 8), int16 wrap-around semantics."""
    a = np.asarray(a, np.int16)
    b = np.asarray(b, np.int16)
    rows, inner = a.shape
    assert b.shape == (inner, rc.N)
    assert np.all(np.abs(a) < 128), "context immediate field is 8-bit"
    m = Machine()
    m.poke_vector(ADDR_V, b.reshape(-1))                      # B row-major
    for k in range(inner):                                    # contexts: A column k
        op = rc.OP_CMUL if k == 0 else rc.OP_CMAC
        words = [rc.encode_context(op, int(a[r, k]) & 0xFF) if r < rows
                 else rc.encode_context(rc.OP_CMUL, 0) for r in range(rc.N)]
        m.poke_contexts(ADDR_CTX + rc.N * k, words)
    prog = matmul_program(rows, inner)
    cycles = m.run(prog)
    out = m.peek_vector(ADDR_OUT, rc.N * rows).reshape(rows, rc.N)
    return RunResult(out, cycles, len(prog), m)


def run_rotation_points(angle_q7: tuple[int, int], points: np.ndarray) -> RunResult:
    """Composite II analogue: rotate 8 2D points by a 2x2 fixed-point matrix
    [[c, -s], [s, c]] with c/s in Q0 integer form (paper's 16-element case)."""
    c, s = angle_q7
    a = np.array([[c, -s], [s, c]], dtype=np.int16)
    pts = np.asarray(points, np.int16)            # (2, 8): row 0 = x, row 1 = y
    return run_matmul(a, pts)


# int16 wrap-around oracles ---------------------------------------------------

def oracle_translation(u, v):
    with np.errstate(over="ignore"):
        return (np.asarray(u, np.int16) + np.asarray(v, np.int16)).astype(np.int16)


def oracle_scaling(u, c):
    with np.errstate(over="ignore"):
        return (np.int16(c) * np.asarray(u, np.int16)).astype(np.int16)


def oracle_matmul(a, b):
    with np.errstate(over="ignore"):
        a16 = np.asarray(a, np.int16)
        b16 = np.asarray(b, np.int16)
        acc = np.zeros((a16.shape[0], rc.N), np.int16)
        for k in range(a16.shape[1]):
            acc = (acc + a16[:, k:k + 1] * b16[k:k + 1, :]).astype(np.int16)
        return acc
