"""Faithful-reproduction layer: MorphoSys M1 emulator (mULATE analogue).

The paper maps linear-algebraic functions (vector-vector "translation",
vector-scalar "scaling", matrix-matrix "rotation/composite") onto the
MorphoSys M1 coarse-grained reconfigurable array and reports cycle counts
against Intel 80386/80486/Pentium instruction-level cycle models.

This package re-implements:
  * the 8x8 RC array, double-banked frame buffer and context memory
    (``rc_array``),
  * the TinyRISC control-ISA subset used by the paper's listings, with
    1-instruction/cycle accounting (``isa``),
  * program generators for the paper's Table 1 / Table 2 routines plus the
    section-5.3 matrix mapping (``programs``),
  * the Intel cycle models of Tables 3-4 and the published Table 5 constants
    (``intel``).
"""
from repro.core.morphosys.rc_array import FrameBuffer, RCArray, ContextMemory, encode_context, decode_context
from repro.core.morphosys.isa import Machine, Program, I
from repro.core.morphosys import programs, intel

__all__ = [
    "FrameBuffer", "RCArray", "ContextMemory", "encode_context", "decode_context",
    "Machine", "Program", "I", "programs", "intel",
]
