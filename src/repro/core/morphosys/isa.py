"""TinyRISC control-ISA subset + cycle accounting.

The paper's listings (Tables 1-2) are straight-line TinyRISC programs whose
*line index* is the cycle count: Table 1 occupies addresses 0..96 and Table 5
reports 96 cycles for the 64-element translation; Table 2 occupies 0..55 and
Table 5 reports 55 cycles.  We therefore account

    cycles(program) = len(program) - 1

i.e. the issue time of the last instruction relative to the first, with one
instruction issued per cycle and RC-array / DMA activity overlapped (DMA
completion is represented by explicit ``nop`` wait slots exactly as the
"..." gaps in the paper's tables do).

DMA wait model: Table 1/2 hide 31 wait slots behind each 64-element frame
buffer load, i.e. ~0.484 cycles per 16-bit element.  We generalise to

    dma_wait(n) = max(1, round(31 * n / 64))

which reproduces the paper's published 8-element cycle counts (21 and 14)
as well as the 64-element ones (96 and 55).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.morphosys.rc_array import (
    N, ContextMemory, FrameBuffer, RCArray,
)


def dma_wait(n_elements: int) -> int:
    """Wait slots hidden behind a frame-buffer DMA of ``n_elements`` int16."""
    return max(1, round(31 * n_elements / 64))


@dataclasses.dataclass(frozen=True)
class I:
    """One TinyRISC instruction: mnemonic + operands (kwargs)."""
    op: str
    args: tuple[Any, ...] = ()

    def __repr__(self) -> str:  # readable program dumps
        return f"{self.op} {', '.join(map(str, self.args))}"


Program = list  # list[I]


class Machine:
    """Executes straight-line TinyRISC programs against the M1 datapath."""

    def __init__(self) -> None:
        self.regs = [0] * 16
        self.fb = FrameBuffer()
        self.ctx = ContextMemory()
        self.rc = RCArray()
        # element-addressed main memory for data, word-addressed for contexts
        self.main = {}      # addr -> np.int16 scalar
        self.main_ctx = {}  # addr -> uint32 context word
        self.cycles = 0
        self.trace: list[str] = []

    # -- host-side helpers (not instructions; model the "main memory" that
    #    the TinyRISC program assumes has been DMA'd in from the host) ------
    def poke_vector(self, addr: int, data: np.ndarray) -> None:
        for i, v in enumerate(np.asarray(data, dtype=np.int16)):
            self.main[addr + i] = np.int16(v)

    def peek_vector(self, addr: int, count: int) -> np.ndarray:
        return np.array([self.main.get(addr + i, np.int16(0)) for i in range(count)],
                        dtype=np.int16)

    def poke_contexts(self, addr: int, words: list[int]) -> None:
        for i, w in enumerate(words):
            self.main_ctx[addr + i] = np.uint32(w)

    # -- execution ----------------------------------------------------------
    def run(self, program: Program) -> int:
        """Execute; returns the paper-style cycle count (len - 1)."""
        for inst in program:
            self._exec(inst)
        self.cycles = len(program) - 1
        return self.cycles

    def _exec(self, inst: I) -> None:
        getattr(self, f"_op_{inst.op}")(*inst.args)
        self.trace.append(repr(inst))

    # -- instruction semantics ----------------------------------------------
    def _op_nop(self) -> None:                       # add r0, r0, r0
        pass

    def _op_ldui(self, rd: int, imm: int) -> None:   # rd <- imm << 16
        self.regs[rd] = (imm & 0xFFFF) << 16

    def _op_ldli(self, rd: int, imm: int) -> None:   # rd[15:0] <- imm
        self.regs[rd] = (self.regs[rd] & 0xFFFF0000) | (imm & 0xFFFF)

    def _op_ldfb(self, addr_reg: int, fb_set: int, bank: int,
                 fb_addr: int, count: int) -> None:
        """DMA ``count`` int16 elements main->frame buffer."""
        src = self.regs[addr_reg]
        data = self.peek_vector(src, count)
        self.fb.write(fb_set, bank, fb_addr, data)

    def _op_ldctxt(self, addr_reg: int, block: str, plane: int,
                   start: int, count: int) -> None:
        src = self.regs[addr_reg]
        words = np.array([self.main_ctx.get(src + i, np.uint32(0))
                          for i in range(count)], dtype=np.uint32)
        self.ctx.load(block, plane, start, words)

    def _op_dbcdc(self, col: int, ctx_word: int, fb_set: int,
                  addr_a: int, addr_b: int) -> None:
        """Double-bank column broadcast (Table 1): col executes ctx on A,B."""
        a = self.fb.read(fb_set, 0, addr_a, N)
        b = self.fb.read(fb_set, 1, addr_b, N)
        self.rc.exec_column(col, self.ctx.get("col", 0, ctx_word), a, b)

    def _op_sbcb(self, col: int, ctx_word: int, fb_set: int,
                 bank: int, addr: int) -> None:
        """Single-bank column broadcast (Table 2): immediate in context."""
        a = self.fb.read(fb_set, bank, addr, N)
        self.rc.exec_column(col, self.ctx.get("col", 0, ctx_word), a, None)

    def _op_sbrb(self, fb_set: int, bank: int, addr: int) -> None:
        """Single-bank *row* broadcast (section 5.3 matrix mapping): every
        row executes its own row-context word on the broadcast B row."""
        b_row = self.fb.read(fb_set, bank, addr, N)
        words = [self.ctx.get("row", 0, r) for r in range(N)]
        self.rc.exec_row_all(words, b_row)

    def _op_wfbi(self, col: int, fb_set: int, addr: int) -> None:
        self.fb.write(fb_set, 1, addr, self.rc.read_column(col))

    def _op_wfbr(self, row: int, fb_set: int, addr: int) -> None:
        """Row-mode write-back used by the matrix mapping."""
        self.fb.write(fb_set, 1, addr, self.rc.out[row, :])

    def _op_stfb(self, addr_reg: int, fb_set: int, fb_addr: int,
                 count: int) -> None:
        dst = self.regs[addr_reg]
        data = self.fb.read(fb_set, 1, fb_addr, count)
        for i, v in enumerate(data):
            self.main[dst + i] = np.int16(v)
