"""Performance-analysis calculators (paper section 6) + validation helpers.

The paper's methodology: for each (algorithm, system) pair report cycles,
total time at the system clock, elements/cycle, cycles/element, and speedup
as the ratio of execution cycles.  These helpers compute the derived columns
from cycle counts so benchmarks can print paper-format tables from either
the published constants, our emulator, or our Intel models.
"""
from __future__ import annotations

import dataclasses

from repro.core.morphosys.intel import CLOCK_MHZ


@dataclasses.dataclass(frozen=True)
class PerfRow:
    algorithm: str
    system: str
    n_elements: int
    cycles: int
    speedup_vs: float | None       # cycle ratio vs the reference system
    total_time_us: float
    elements_per_cycle: float
    cycles_per_element: float
    source: str                    # "paper" | "emulator" | "model"


def derive(algorithm: str, system: str, n: int, cycles: int,
           ref_cycles: int | None = None, source: str = "model") -> PerfRow:
    clock = CLOCK_MHZ[system]
    return PerfRow(
        algorithm=algorithm,
        system=system,
        n_elements=n,
        cycles=cycles,
        speedup_vs=(cycles / ref_cycles) if ref_cycles else None,
        total_time_us=cycles / clock,
        elements_per_cycle=round(n / cycles, 4),
        cycles_per_element=round(cycles / n, 4),
        source=source,
    )


def format_table(rows: list[PerfRow]) -> str:
    hdr = (f"{'algorithm':<18}{'system':<10}{'n':>4}{'cycles':>8}{'speedup':>9}"
           f"{'us':>10}{'elem/cyc':>10}{'cyc/elem':>10}  source")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        sp = f"{r.speedup_vs:.2f}" if r.speedup_vs else "-"
        lines.append(
            f"{r.algorithm:<18}{r.system:<10}{r.n_elements:>4}{r.cycles:>8}{sp:>9}"
            f"{r.total_time_us:>10.3f}{r.elements_per_cycle:>10.4f}"
            f"{r.cycles_per_element:>10.3f}  {r.source}")
    return "\n".join(lines)
