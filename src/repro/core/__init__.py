"""The paper's primary contribution: linear-algebra mapping + perf analysis.

``morphosys``        -- faithful M1 emulator + Intel cycle models
``transform_engine`` -- the TPU re-expression of the mapping
``analysis``         -- the paper's performance-analysis methodology
"""
from repro.core import analysis, transform_engine
from repro.core import morphosys

__all__ = ["analysis", "transform_engine", "morphosys"]
