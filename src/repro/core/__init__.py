"""The paper's primary contribution: linear-algebra mapping + perf analysis.

``morphosys``        -- faithful M1 emulator + Intel cycle models
``transform_engine`` -- the TPU re-expression of the mapping
``transform_chain``  -- fused composite-chain compiler (one-pass lowering)
``analysis``         -- the paper's performance-analysis methodology
"""
from repro.core import analysis, transform_chain, transform_engine
from repro.core import morphosys

__all__ = ["analysis", "transform_chain", "transform_engine", "morphosys"]
