"""Fused transform-chain compiler: the paper's one-pass composite, lazily.

The paper's "General Composite Algorithm using Matrix Algorithm" collapses
an arbitrary translate/scale/rotate pipeline into a single pass over the RC
array.  ``TransformChain`` is that idea as a small compiler:

  1. **IR** -- builder calls (``translate``/``scale``/``rotate``/``affine``/
     ``matrix``, 2D and 3D homogeneous) record primitives only; no jnp work
     happens until ``apply``, so composing a chain is allocation-free.
  2. **Fold** -- the recorded chain folds algebraically: adjacent translates
     sum, scales multiply, scale+translate fuse into one affine (s, t), and
     anything containing a rotation or a custom matrix folds into a single
     composed (A, t) pair.  Chains whose structure is pure-diagonal
     (translate/scale/affine only) never build a matrix and never touch the
     MXU.  Chains containing a *projective* primitive (``projective``/
     ``cull`` -- the graphics companion paper's viewing pipelines) fold one
     level up, in homogeneous space: everything between perspective divides
     collapses into a single (d+1, d+1) matrix H plus axis-aligned cull
     bounds, because affines compose into H on either side of a divide
     (the divide is a projective equivalence) -- so a full model -> camera
     -> projection -> cull -> viewport chain is ONE (H, lo, hi) triple and
     ONE divide.  The fold itself is O(k d^2) scalar work and runs
     host-side in numpy -- one shared code path for single-request
     ``apply`` and the serving engine, so a request folds to bit-identical
     composed parameters however it is dispatched (see the folding section
     note).
  3. **Lower** -- the folded chain lowers to ONE fused lane-dense Pallas
     kernel over the flattened point buffer -- one HBM read of the points,
     one write, with the composed parameters staged as (1, w) context-word
     rows: ``kernels.chain_diag`` for diagonal plans, ``kernels.chain_apply``
     (2d-1 lane-rolled multiply-adds) for general plans, and
     ``kernels.chain_project`` (a second rolled MAC set for the homogeneous
     w + in-kernel divide + cull mask) for projective plans.  The plan
     kinds form a lattice -- diag (s, t) is the diagonal of matrix (A, t),
     which is the affine block of projective (H, lo, hi) -- and every
     structure lowers to the cheapest kind that can express it.
  4. **Plan cache** -- compiled plans are cached by *chain structure* +
     backend, and the jitted plan function takes the folded parameter
     values as arguments, so the serving hot path (same chain shape, fresh
     parameter values every request) recompiles and retraces nothing.

Byte economy vs. sequential primitive dispatch (k-long chain over N points
of dim d, itemsize 4): sequential moves ~2*k*N*d*4 bytes HBM<->VMEM; the
fused plan moves 2*N*d*4 + O(1).  ``kernels.opcount`` makes this testable.

``repro.serving.GeometryServer`` layers plan-bucketed batched serving on
top of this compiler: same-structure requests pack into one (B, L, d)
batch and execute through the batched forms of the same chain kernels --
one launch per bucket instead of one per request.

Affine chains also compile to a second execution lane:
``apply(..., dtype="q8.7")`` runs the M1-faithful int16 Qm.n fixed-point
plan (``kernels.fixedpoint``) -- the fold is the same shared host fold,
the folded parameters are quantised once per request
(``repro.quantize``), and the fused kernel moves HALF the HBM bytes per
point.  Projective chains stay float (the in-kernel divide has no
single-shift Qm.n form) and are rejected with a ValueError.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro import errors, quantize
from repro.autotune import cache as tuning
from repro.kernels import dispatch, opcount
from repro.obs import trace as obst
from repro.kernels.affine import chain_diag as _k_chain_diag
from repro.kernels.fixedpoint import chain_apply_q as _k_chain_apply_q
from repro.kernels.fixedpoint import chain_diag_q as _k_chain_diag_q
from repro.kernels.matmul import chain_apply as _k_chain_apply
from repro.kernels.projective import chain_project as _k_chain_project

# primitive kinds: T translate, S scale, R rotate, A affine(s, t), M matrix,
# P projective (full homogeneous matrix), C cull (axis-aligned bounds)
_DIAG_KINDS = frozenset("TSA")
_PROJ_KINDS = frozenset("PC")
_AXES = {"x": 0, "y": 1, "z": 2}

#: plan-cache / trace statistics (observable by tests and benchmarks):
#:   compiles -- plans built (structure-level cache misses)
#:   hits     -- plans served from the cache
#:   traces   -- executions of a plan body under jax tracing (a cached plan
#:               applied at a seen shape/dtype must not bump this)
stats = {"compiles": 0, "hits": 0, "traces": 0}


def _count_trace(kernel: str, backend: str) -> None:
    """Jit re-trace bookkeeping: the counter plus (when a tracer is
    installed) a ``plan.trace`` instant -- re-traces are where compile
    latency hides, so the trace marks each one with its kernel."""
    stats["traces"] += 1
    trc = obst.active()
    if trc.enabled:
        trc.instant("plan.trace", cache="chain", kernel=kernel,
                    backend=backend)

_PLAN_CACHE: dict[tuple, "Plan"] = {}


def clear_plan_cache() -> None:
    """Drop all compiled plans (benchmarks use this to measure cold cost)."""
    _PLAN_CACHE.clear()


def reset_stats() -> None:
    """Zero every chain counter (benchmarks snapshot deltas from here)."""
    for k in stats:
        stats[k] = 0


# -- folding (host-side numpy float32) ---------------------------------------
#
# The fold runs on the host, in numpy, NOT inside the jitted plan.  That is
# a determinism decision, not a performance one (either way it is O(k d^2)
# scalar work): XLA:CPU takes per-program freedom in fusing float
# multiply-adds (FMA contraction, operand association -- both observed, and
# neither controllable: optimization barriers and bitcast fences are folded
# away by the algebraic simplifier), so the "same" fold traced at two
# different batch shapes can differ in its last ULP.  One shared host fold
# means a request folds to *bit-identical* composed parameters whether it
# is applied alone or packed into a serving bucket, leaving the fused
# kernel application as the only XLA-shaped code -- see
# ``serving.engine`` for the resulting equality contract.

def _vec(v, dim: int) -> np.ndarray:
    v = np.asarray(v, np.float32)
    if v.ndim == 0:
        v = np.broadcast_to(v, (dim,))
    return v.reshape(dim)


def _rot(dim: int, axis: int, theta) -> np.ndarray:
    """Right-multiply (row-vector) rotation matrix: q = p @ R."""
    c = np.cos(np.float32(theta), dtype=np.float32)
    s = np.sin(np.float32(theta), dtype=np.float32)
    if dim == 2:
        return np.array([[c, s], [-s, c]], np.float32)
    r = np.eye(3, dtype=np.float32)
    i, j = [(1, 2), (2, 0), (0, 1)][axis]   # rotation plane for axis x/y/z
    r[i, i] = r[j, j] = c
    r[i, j], r[j, i] = s, -s
    return r


def _mat_parts(val, dim: int) -> tuple[np.ndarray, np.ndarray]:
    """Split a custom-matrix param into (A (d,d), t (d,)); accepts a (d, d)
    linear matrix or a (d+1, d+1) AFFINE homogeneous one (row-vector
    convention).  A homogeneous matrix with a nontrivial perspective
    column is rejected -- silently dropping that column would compute the
    wrong transform; such matrices belong in ``projective``."""
    m = np.asarray(val, np.float32)
    if m.shape == (dim + 1, dim + 1):
        # tolerance scaled to the matrix magnitude: computed affines may
        # carry round-off residue in the perspective column, which is
        # numerically irrelevant; a REAL perspective column is orders of
        # magnitude above it
        tol = 1e-6 * max(1.0, float(np.abs(m).max()))
        if np.any(np.abs(m[:dim, dim]) > tol) or abs(m[dim, dim] - 1.0) > tol:
            raise ValueError(
                "matrix() requires an affine homogeneous matrix (last "
                f"column [0, ..., 0, 1]); got last column {m[:, dim]} -- "
                "use projective() for perspective matrices")
        return m[:dim, :dim], m[dim, :dim]
    if m.shape == (dim, dim):
        return m, np.zeros((dim,), np.float32)
    raise ValueError(f"matrix must be ({dim},{dim}) or "
                     f"({dim + 1},{dim + 1}); got {m.shape}")


def _fold_diag(dim: int, kinds, params,
               carry=None) -> tuple[np.ndarray, np.ndarray]:
    """Fold a pure-diagonal chain to (s, t) with q = s (.) p + t.

    ``carry`` resumes the fold from a saved (s, t) state instead of the
    identity -- the loop body is shared, so resuming is bit-identical to
    folding the concatenated chain in one call (see ``fold_carry_extend``).
    """
    if carry is None:
        s = np.ones((dim,), np.float32)
        t = np.zeros((dim,), np.float32)
    else:
        s, t = carry
    for (kind, _), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec(val, dim)
        elif kind == "S":
            v = _vec(val, dim)
            s, t = s * v, t * v
        else:                                   # "A": y = v*y + u
            v, u = _vec(val[0], dim), _vec(val[1], dim)
            s, t = s * v, t * v + u
    return s, t


def _fold_matrix(dim: int, kinds, params,
                 carry=None) -> tuple[np.ndarray, np.ndarray]:
    """Fold a general chain to (A, t) with q = p @ A + t.

    ``carry`` resumes the fold from a saved (A, t) state (same loop body
    as the from-identity fold, hence bit-identical -- see
    ``fold_carry_extend``)."""
    if carry is None:
        a = np.eye(dim, dtype=np.float32)
        t = np.zeros((dim,), np.float32)
    else:
        a, t = carry
    for (kind, axis), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec(val, dim)
        elif kind == "S":
            v = _vec(val, dim)
            a, t = a * v[None, :], t * v
        elif kind == "A":
            v, u = _vec(val[0], dim), _vec(val[1], dim)
            a, t = a * v[None, :], t * v + u
        elif kind == "R":
            r = _rot(dim, axis, val)
            a, t = a @ r, t @ r
        else:                                   # "M"
            m, u = _mat_parts(val, dim)
            a, t = a @ m, t @ m + u
    return a, t


def _homo(dim: int, a: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Embed an affine (A, t) as a (d+1, d+1) homogeneous row-vector
    matrix: [p, 1] @ H = [p @ A + t, 1]."""
    h = np.zeros((dim + 1, dim + 1), np.float32)
    h[:dim, :dim] = a
    h[dim, :dim] = t
    h[dim, dim] = 1.0
    return h


def _map_bounds(lo: np.ndarray, hi: np.ndarray, s: np.ndarray,
                t: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Push axis-aligned [lo, hi] bounds through a per-coordinate affine
    y = s*x + t (negative scales swap the endpoints).  Only called once a
    cull has been recorded, so the bounds are finite."""
    a, b = s * lo + t, s * hi + t
    return np.minimum(a, b), np.maximum(a, b)


def _fold_projective(dim: int, kinds, params, carry=None):
    """Fold a projective chain to (H (d+1, d+1), lo (d,), hi (d,)) with
    q = divide([p, 1] @ H) and an inclusive axis-aligned cull against
    [lo, hi] in the OUTPUT space (+-inf where no cull was recorded).

    The homogeneous composition collapses everything around the divide:
    a divide is a projective equivalence ([q/w, 1] ~ [q, w]), so affines
    and projectives on either side all fold into one H with one divide at
    the end.  Cull bounds are recorded in the coordinate space where the
    ``cull`` primitive sits and pushed forward through later diagonal
    primitives (a viewport map); a rotation, custom matrix, or projective
    AFTER a cull would need non-axis-aligned bounds and is rejected.

    Returns the full carry (h, lo, hi, culled); ``fold_structure`` strips
    the ``culled`` flag.  ``carry`` resumes from a saved state (same loop
    body, hence bit-identical -- see ``fold_carry_extend``); the carried
    ``culled`` flag keeps the after-cull primitive restrictions exact
    across the resume boundary.
    """
    if carry is None:
        h = np.eye(dim + 1, dtype=np.float32)
        lo = np.full((dim,), -np.inf, np.float32)
        hi = np.full((dim,), np.inf, np.float32)
        culled = False
    else:
        h, lo, hi, culled = carry
    for (kind, axis), val in zip(kinds, params):
        if kind == "C":
            lo = np.maximum(lo, _vec(val[0], dim))
            hi = np.minimum(hi, _vec(val[1], dim))
            culled = True
            continue
        if kind == "T":
            t = _vec(val, dim)
            hk = _homo(dim, np.eye(dim, dtype=np.float32), t)
            if culled:
                lo, hi = lo + t, hi + t
        elif kind == "S":
            s = _vec(val, dim)
            hk = _homo(dim, np.diag(s), np.zeros((dim,), np.float32))
            if culled:
                lo, hi = _map_bounds(lo, hi, s, np.float32(0.0))
        elif kind == "A":
            s, t = _vec(val[0], dim), _vec(val[1], dim)
            hk = _homo(dim, np.diag(s), t)
            if culled:
                lo, hi = _map_bounds(lo, hi, s, t)
        elif kind in ("R", "M"):
            if culled:
                raise ValueError(
                    "only translate/scale/affine may follow cull() in a "
                    f"projective chain (got {kind!r}): axis-aligned cull "
                    "bounds cannot fold through a rotation or custom matrix")
            if kind == "R":
                hk = _homo(dim, _rot(dim, axis, val),
                           np.zeros((dim,), np.float32))
            else:
                hk = _homo(dim, *_mat_parts(val, dim))
        else:                                   # "P"
            if culled:
                raise ValueError("a projective primitive cannot follow "
                                 "cull(): record the cull after the last "
                                 "projection instead")
            hk = np.asarray(val, np.float32)
            if hk.shape != (dim + 1, dim + 1):
                raise ValueError(f"projective matrix must be "
                                 f"({dim + 1},{dim + 1}); got {hk.shape}")
        h = (h @ hk).astype(np.float32)
    return h, lo, hi, culled


# -- traced-parameter fallback (jnp fold) ------------------------------------
#
# The host fold requires concrete parameter values.  When a caller traces
# chain *parameters* -- jax.grad over a rotation angle, jit over a pose --
# ``apply`` instead folds in jnp inside the caller's own trace and calls
# the fused kernel entry directly (plan caching is moot there: the caller's
# jit already owns compilation).  This path is differentiable; it is NOT
# part of the serving bit-identity contract, which is about concrete
# parameters.

def _vec_jnp(v, dim: int):
    v = jnp.asarray(v, jnp.float32)
    return jnp.broadcast_to(v, (dim,)) if v.ndim == 0 else v.reshape(dim)


def _rot_jnp(dim: int, axis: int, theta):
    c = jnp.cos(jnp.asarray(theta, jnp.float32))
    s = jnp.sin(jnp.asarray(theta, jnp.float32))
    if dim == 2:
        return jnp.eye(2, dtype=jnp.float32) * c + \
            jnp.array([[0.0, 1.0], [-1.0, 0.0]], jnp.float32) * s
    i, j = [(1, 2), (2, 0), (0, 1)][axis]
    r = jnp.eye(3, dtype=jnp.float32).at[i, i].set(c).at[j, j].set(c)
    return r.at[i, j].set(s).at[j, i].set(-s)


def _fold_jnp(dim: int, kinds, params):
    a = jnp.eye(dim, dtype=jnp.float32)
    t = jnp.zeros((dim,), jnp.float32)
    for (kind, axis), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec_jnp(val, dim)
        elif kind == "S":
            v = _vec_jnp(val, dim)
            a, t = a * v[None, :], t * v
        elif kind == "A":
            v, u = _vec_jnp(val[0], dim), _vec_jnp(val[1], dim)
            a, t = a * v[None, :], t * v + u
        elif kind == "R":
            r = _rot_jnp(dim, axis, val)
            a, t = a @ r, t @ r
        else:                                   # "M"
            m = jnp.asarray(val, jnp.float32)
            if m.shape == (dim + 1, dim + 1):
                m, u = m[:dim, :dim], m[dim, :dim]
            else:
                u = jnp.zeros((dim,), jnp.float32)
            a, t = a @ m, t @ m + u
    return a, t


def _params_traced(params) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves(params))


def structure_is_diagonal(structure: tuple) -> bool:
    """True if ``structure`` (a ``TransformChain.structure`` value) folds to
    a diagonal (s, t) plan -- translate/scale/affine primitives only."""
    _, kinds = structure
    return all(k in _DIAG_KINDS for k, _ in kinds)


def structure_is_projective(structure: tuple) -> bool:
    """True if ``structure`` folds to a projective (H, lo, hi) plan --
    it contains a projective matrix or a cull primitive."""
    _, kinds = structure
    return any(k in _PROJ_KINDS for k, _ in kinds)


def plan_kind_of(structure: tuple) -> str:
    """The plan-kind lattice resolution for a structure: the cheapest of
    diag < matrix < projective that can express it."""
    if structure_is_projective(structure):
        return "projective"
    return "diag" if structure_is_diagonal(structure) else "matrix"


def fold_structure(structure: tuple, params) -> tuple[np.ndarray, ...]:
    """Fold ONE parameter set for ``structure``: float32 (s, t) if the
    structure is diagonal, (A, t) if it is a general affine, and
    (H, lo, hi) if it is projective.  This host fold is shared verbatim
    by ``TransformChain.apply``, the serving engine's bucket packing, and
    (through the carry API below) the scene graph's cached subchain
    folds, so a request's composed parameters are bit-identical however
    it is dispatched."""
    kind = plan_kind_of(structure)
    dim = structure[0]
    carry = fold_carry_extend(kind, dim, fold_carry_identity(kind, dim),
                              structure[1], params)
    return fold_carry_finish(kind, carry)


# -- incremental (carry-state) folds ----------------------------------------
#
# The scene graph (``repro.scene``) folds shared chain prefixes once and
# extends each node's world fold from its parent's saved state.  For the
# bitwise contract to survive that, the incremental fold must BE the
# one-pass fold: the ``_fold_*`` loops above thread an explicit carry, and
# ``fold_carry_extend`` re-enters the same loop with a saved carry.  The
# op sequence is therefore *identical* to folding the concatenated chain
# in one ``fold_structure`` call -- bit-identical results by construction,
# not by accident of float algebra.  The carry per plan kind:
#
#   diag        (s, t)                  q = s (.) p + t
#   matrix      (A, t)                  q = p @ A + t
#   projective  (H, lo, hi, culled)     q = divide([p, 1] @ H), cull flag
#                                       carried so the after-cull
#                                       restrictions stay exact
#
# A subchain folds under the plan kind of the FULL chain it belongs to
# (the lattice diag < matrix < projective is monotone under
# concatenation), so a diagonal prefix extended by a rotating suffix is
# folded with the matrix loop from the start -- exactly what the one-pass
# fold of the whole chain does.

def fold_carry_identity(kind: str, dim: int) -> tuple:
    """The identity carry state for an incremental fold of plan kind
    ``kind`` ("diag" | "matrix" | "projective") in ``dim`` dimensions --
    ``fold_carry_extend`` from this state reproduces ``fold_structure``
    bit for bit."""
    if kind == "diag":
        return (np.ones((dim,), np.float32), np.zeros((dim,), np.float32))
    if kind == "matrix":
        return (np.eye(dim, dtype=np.float32),
                np.zeros((dim,), np.float32))
    if kind == "projective":
        return (np.eye(dim + 1, dtype=np.float32),
                np.full((dim,), -np.inf, np.float32),
                np.full((dim,), np.inf, np.float32),
                False)
    raise ValueError(f"unknown fold kind {kind!r}")


def fold_carry_extend(kind: str, dim: int, carry: tuple, kinds,
                      params) -> tuple:
    """Extend a saved fold carry by the primitives ``(kinds, params)`` --
    the incremental half of ``fold_structure``.  Running the concatenated
    chain through one ``fold_structure`` call and running it here in
    pieces execute the SAME loop over the same primitives, so the two are
    bit-identical; the scene graph's fold-CSE correctness rests on this.

    ``kind`` must be the plan kind of the FULL chain the pieces belong to
    (``plan_kind_of`` of the concatenated structure); a subchain whose
    primitives cannot be expressed under ``kind`` raises ``ValueError``.
    """
    have = {k for k, _ in kinds}
    if kind == "diag":
        if have - _DIAG_KINDS:
            raise ValueError(f"subchain kinds {have - _DIAG_KINDS} cannot "
                             "fold under a diagonal carry")
        return _fold_diag(dim, kinds, params, carry)
    if kind == "matrix":
        if have & _PROJ_KINDS:
            raise ValueError(f"subchain kinds {have & _PROJ_KINDS} cannot "
                             "fold under an affine-matrix carry")
        return _fold_matrix(dim, kinds, params, carry)
    if kind == "projective":
        return _fold_projective(dim, kinds, params, carry)
    raise ValueError(f"unknown fold kind {kind!r}")


def fold_carry_finish(kind: str, carry: tuple) -> tuple[np.ndarray, ...]:
    """Turn a fold carry into the public folded-parameter tuple that
    ``fold_structure`` returns (drops the projective carry's internal
    ``culled`` flag)."""
    return carry[:3] if kind == "projective" else carry


# -- plans -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled chain: ``fn(folded, flat_points_2d) -> out`` (jitted),
    where ``folded`` is the host-folded (s, t) / (A, t) / (H, lo, hi)
    tuple.  Projective plans return ``(projected, mask)``.  Fixed-point
    plans (``qformat`` set) take int16 Qm.n words instead -- the folded
    parameters quantised once per request by ``quantize.quantize_fold``
    -- and return int16."""
    kind: str                      # "diag" | "matrix" | "projective"
    dim: int
    backend: str
    length: int                    # primitives folded into this plan
    fn: typing.Callable
    qformat: str | None = None     # Qm.n name for fixed-point plans


def _compile_q(structure: tuple, backend: str, qname: str) -> Plan:
    """Compile a fixed-point plan: the same body discipline as the float
    plans (tuning consult at trace time, one fused kernel), but lowering
    to the int16 ``chain_*_q`` kernels with the format's fraction count
    baked in as the requantising shift.  Only affine structures get here
    -- ``TransformChain`` rejects projective + dtype before lookup."""
    dim, kinds = structure
    kind = plan_kind_of(structure)
    fmt = quantize.as_qformat(qname)

    if kind == "diag":
        def body(folded_q, pts2):
            """Jitted q-format diagonal scale+translate over (N, dim)."""
            _count_trace("chain_diag_q", backend)
            s, t = folded_q
            cfg = tuning.config_for("chain_diag_q", backend, fmt.name,
                                    pts2.shape[0])
            return _k_chain_diag_q(pts2, s, t, n_frac=fmt.n,
                                   backend=backend, config=cfg)
    else:
        def body(folded_q, pts2):
            """Jitted q-format fused matmul+translate over (N, dim)."""
            _count_trace("chain_apply_q", backend)
            a, t = folded_q
            cfg = tuning.config_for("chain_apply_q", backend, fmt.name,
                                    pts2.shape[0])
            return _k_chain_apply_q(pts2, a, t, n_frac=fmt.n,
                                    backend=backend, config=cfg)

    return Plan(kind=kind, dim=dim, backend=backend, length=len(kinds),
                fn=jax.jit(body), qformat=fmt.name)


def _compile(structure: tuple, backend: str) -> Plan:
    dim, kinds = structure
    kind = plan_kind_of(structure)

    # The tuning-cache consult happens inside the plan body, i.e. at
    # TRACE time: point shapes are concrete there, so the lookup keys on
    # the actual size class, and a cached plan applied at a seen shape
    # re-consults nothing (the config is baked into the trace, which is
    # why ``repro.autotune.set_enabled`` clears the plan cache).  With
    # tuning disabled this returns the deterministic defaults; any config
    # is bit-identical (staging-only knobs), so tuned and untuned plans
    # agree bitwise.
    if kind == "diag":
        def body(folded, pts2):
            """Jitted diagonal scale+translate over (N, dim)."""
            _count_trace("chain_diag", backend)
            s, t = folded
            cfg = tuning.config_for("chain_diag", backend,
                                    str(pts2.dtype), pts2.shape[0])
            return _k_chain_diag(pts2, s, t, backend=backend, config=cfg)
    elif kind == "matrix":
        def body(folded, pts2):
            """Jitted fused matmul+translate over (N, dim)."""
            _count_trace("chain_apply", backend)
            a, t = folded
            cfg = tuning.config_for("chain_apply", backend,
                                    str(pts2.dtype), pts2.shape[0])
            return _k_chain_apply(pts2, a, t, backend=backend, config=cfg)
    else:
        def body(folded, pts2):
            """Jitted homography apply + perspective divide + cull."""
            _count_trace("chain_project", backend)
            h, lo, hi = folded
            cfg = tuning.config_for("chain_project", backend,
                                    str(pts2.dtype), pts2.shape[0])
            return _k_chain_project(pts2, h, lo, hi, backend=backend,
                                    config=cfg)

    return Plan(kind=kind, dim=dim, backend=backend, length=len(kinds),
                fn=jax.jit(body))


def _get_plan(structure: tuple, backend: str,
              qname: str | None = None) -> Plan:
    key = (structure, backend, qname)
    plan = _PLAN_CACHE.get(key)
    trc = obst.active()
    if plan is None:
        stats["compiles"] += 1
        if trc.enabled:
            trc.instant("plan.compile", cache="chain", backend=backend,
                        q=qname, length=len(structure))
        plan = _compile_q(structure, backend, qname) if qname is not None \
            else _compile(structure, backend)
        _PLAN_CACHE[key] = plan
    else:
        stats["hits"] += 1
        if trc.enabled:
            trc.instant("plan.hit", cache="chain", backend=backend,
                        q=qname, length=len(structure))
    return plan


# -- the chain IR ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformChain:
    """Lazy composite-transform IR.  Builder methods append primitives
    without any jnp work; ``apply`` folds + lowers through the plan cache.

        chain = (TransformChain.identity(dim=2)
                 .scale(2.0, 0.5).rotate(0.3).translate(1.0, -2.0))
        q = chain.apply(points)            # one fused kernel launch
    """
    dim: int
    kinds: tuple = ()              # ((kind, axis), ...) -- the structure
    params: tuple = ()             # raw per-primitive parameter values

    @staticmethod
    def identity(dim: int = 2) -> "TransformChain":
        """An empty chain in ``dim`` dimensions (2 or 3)."""
        if dim not in (2, 3):
            raise ValueError(f"dim must be 2 or 3, got {dim}")
        return TransformChain(dim=dim)

    def _push(self, kind: str, axis: int, param) -> "TransformChain":
        return TransformChain(self.dim, self.kinds + ((kind, axis),),
                              self.params + (param,))

    def _vec_arg(self, name: str, args):
        if len(args) == 1:
            return args[0]
        if len(args) != self.dim:
            raise ValueError(f"{name} takes 1 or {self.dim} components, "
                             f"got {len(args)}")
        return tuple(args)

    def translate(self, *t) -> "TransformChain":
        """Append q = p + t (scalar broadcast or one component per dim)."""
        return self._push("T", -1, self._vec_arg("translate", t))

    def scale(self, *s) -> "TransformChain":
        """Append q = s (.) p (scalar or per-dim factors)."""
        return self._push("S", -1, self._vec_arg("scale", s))

    def rotate(self, theta, axis=None) -> "TransformChain":
        """Append a rotation by ``theta`` (radians).  3D chains name the
        axis (0/1/2 or "x"/"y"/"z"); 2D chains take none."""
        if self.dim == 2:
            if axis is not None:
                raise ValueError("2D rotations take no axis")
            return self._push("R", -1, theta)
        if axis is None:
            raise ValueError("3D rotations need axis= (0/1/2 or x/y/z)")
        ax = _AXES.get(axis, axis)
        if ax not in (0, 1, 2):
            raise ValueError(f"bad rotation axis {axis!r}")
        return self._push("R", ax, theta)

    def affine(self, s, t) -> "TransformChain":
        """Append the fused q = s (.) p + t (scalars or per-dim vectors)."""
        return self._push("A", -1, (s, t))

    def matrix(self, m) -> "TransformChain":
        """Append a custom (d, d) linear or (d+1, d+1) homogeneous matrix
        (row-vector convention: q = [p, 1] @ M).  The matrix must be
        affine (last column [0, ..., 0, 1]); use ``projective`` for a
        matrix with a nontrivial perspective column."""
        return self._push("M", -1, m)

    def projective(self, m) -> "TransformChain":
        """Append a full (d+1, d+1) projective matrix (row-vector
        convention) -- a perspective or orthographic projection.  The
        chain becomes *projective*: it folds in homogeneous space and its
        plan ends in ONE in-kernel perspective divide (consecutive
        projective/affine primitives keep collapsing into a single H --
        the divide is a projective equivalence)."""
        return self._push("P", -1, m)

    def cull(self, lo=-1.0, hi=1.0) -> "TransformChain":
        """Append an inclusive axis-aligned cull against [lo, hi]^d in the
        CURRENT coordinate space (the NDC frustum cull of a viewing
        pipeline; scalars broadcast, or pass per-dim vectors).  The chain
        becomes projective; its plan emits a per-point inside/outside mask
        (see ``project``).  Only translate/scale/affine (e.g. a viewport
        map) may follow a cull -- the bounds fold through those exactly."""
        return self._push("C", -1, (lo, hi))

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        return len(self.kinds)

    @property
    def structure(self) -> tuple:
        """Hashable plan-cache key component: dims + primitive kinds/axes
        (parameter *values* are plan operands, not part of the key)."""
        return (self.dim, self.kinds)

    @property
    def is_diagonal(self) -> bool:
        """True if the folded chain is diagonal (never touches the MXU)."""
        return all(k in _DIAG_KINDS for k, _ in self.kinds)

    @property
    def is_projective(self) -> bool:
        """True if the chain contains a projective/cull primitive: it
        folds in homogeneous space and its plan ends in a divide."""
        return any(k in _PROJ_KINDS for k, _ in self.kinds)

    @property
    def plan_kind(self) -> str:
        """The plan class this structure lowers to -- the cheapest rung of
        the diag < matrix < projective lattice that can express it:
        "diag" (VPU-only fused affine), "matrix" (lane-rolled
        q = p @ A + t), or "projective" (homogeneous MACs + divide +
        cull mask)."""
        return plan_kind_of(self.structure)

    def fold(self) -> tuple[np.ndarray, ...]:
        """The host fold this chain's plan consumes: float32 (s, t) for
        diagonal structures, (A, t) for general affine ones, (H, lo, hi)
        for projective ones.  Bit-identical wherever it is computed --
        ``apply``, the serving engine, a test -- because it is one shared
        numpy code path (see the folding section note)."""
        return fold_structure(self.structure, self.params)

    def folded(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Eagerly fold to the composed (A (d,d), t (d,)) pair.  Projective
        chains have no affine form -- use ``fold``/``as_homogeneous``."""
        if self.is_projective:
            raise ValueError("projective chains have no (A, t) form; use "
                             "fold() or as_homogeneous()")
        if _params_traced(self.params):
            return _fold_jnp(self.dim, self.kinds, self.params)
        if self.is_diagonal:
            s, t = self.fold()
            return jnp.asarray(np.diag(s)), jnp.asarray(t)
        a, t = self.fold()
        return jnp.asarray(a), jnp.asarray(t)

    def as_homogeneous(self) -> jnp.ndarray:
        """The composed (d+1, d+1) homogeneous matrix (row-vector form).
        For projective chains this is the folded H (the cull bounds are
        not representable in the matrix; see ``fold``)."""
        if self.is_projective:
            h, _, _ = self.fold()
            return jnp.asarray(h)
        a, t = self.folded()
        d = self.dim
        h = jnp.zeros((d + 1, d + 1), jnp.float32)
        h = h.at[:d, :d].set(a).at[d, :d].set(t).at[d, d].set(1.0)
        return h

    # -- execution -----------------------------------------------------------

    def _plan(self, backend: str | None, qname: str | None = None) -> Plan:
        return _get_plan(self.structure, dispatch.resolve(backend), qname)

    def _record_fused(self, plan: Plan, flat: jnp.ndarray, d: int) -> None:
        # one shared table (opcount) for parameter words and HBM passes
        # per plan kind -- the same accounting costmodel.chain_cost
        # predicts and the serving engine records per packed launch.
        # Fixed-point plans move 2-byte words throughout (the suffix keeps
        # the lanes separately countable).
        suffix = "_q" if plan.qformat else ""
        opcount.record(
            f"chain_fused_{plan.kind}{suffix}",
            opcount.fused_chain_bytes(flat.shape[0], d, kind=plan.kind,
                                      itemsize=flat.dtype.itemsize))

    def _apply_q(self, points: jnp.ndarray, fmt,
                 backend: str | None) -> jnp.ndarray:
        """The fixed-point lane of ``apply``: fold in float (the SAME host
        fold), quantise the folded parameters once, and run the int16
        ``chain_*_q`` plan.  Float points are quantised in (saturating,
        via the traced jnp quantiser -- bit-identical to the host one, no
        device round-trip, and jit over the POINTS works) and the result
        dequantised back to float32; int16 points are treated as
        already-Qm.n words and come back as int16."""
        fmt = quantize.as_qformat(fmt)
        quantize.reject_projective(self.is_projective)
        if _params_traced(self.params):
            raise NotImplementedError(
                "fixed-point chains require concrete parameters (the fold "
                "is quantised host-side)")
        d = points.shape[-1]
        from_float = quantize.points_need_quantize(points.dtype)
        pts_q = fmt.quantize_jnp(points) if from_float \
            else jnp.asarray(points)
        flat = pts_q.reshape(-1, d)
        plan = self._plan(backend, fmt.name)
        self._record_fused(plan, flat, d)
        folded_q = quantize.quantize_fold(self.fold(), plan.kind, fmt)
        out = plan.fn(folded_q, flat).reshape(points.shape)
        return fmt.dequantize_jnp(out) if from_float else out

    def apply(self, points: jnp.ndarray, *, backend: str | None = None,
              dtype: str | None = None) -> jnp.ndarray:
        """Apply the folded chain to (..., d) points in one fused pass.

        Concrete parameters go through the cached plan (host fold, see the
        folding section note); parameters that are jax tracers fold in jnp
        inside the caller's trace instead, so grad/jit over chain
        parameters (pose optimisation) stays differentiable (affine chains
        only).  Projective chains return the projected points; use
        ``project`` to also get the frustum-cull mask.

        ``dtype`` selects the execution lane: ``None`` is the float32
        lane; a Qm.n name ("q8.7") runs the M1-faithful int16 fixed-point
        lane -- same fold, parameters quantised once per plan, half the
        HBM bytes per point (affine chains only; projective chains are
        rejected).

        Malformed points raise the typed ``repro.errors`` taxonomy at
        this boundary (``ShapeError`` / ``EmptyPointsError`` /
        ``DtypeError`` -- all ``ValueError`` subclasses) instead of
        detonating inside the fused kernel: empty point sets and float64
        buffers used to be silently accepted here."""
        errors.check_points(points, self.dim)
        d = points.shape[-1]
        if not self.kinds:
            return points
        if dtype is not None:
            return self._apply_q(points, dtype, backend)
        flat = points.reshape(-1, d)
        if _params_traced(self.params):
            if self.is_projective:
                raise NotImplementedError(
                    "projective chains require concrete parameters (the "
                    "homogeneous fold runs host-side)")
            # chain parameters are jax tracers (grad/jit over a pose):
            # fold in jnp inside the caller's trace, differentiably
            opcount.record("chain_fused_traced",
                           2 * flat.nbytes + 4 * (d * d + d))
            a, t = _fold_jnp(d, self.kinds, self.params)
            out = _k_chain_apply(flat, a, t, backend=backend)
            return out.reshape(points.shape)
        plan = self._plan(backend)
        self._record_fused(plan, flat, d)
        out = plan.fn(self.fold(), flat)
        if plan.kind == "projective":
            out = out[0]
        return out.reshape(points.shape)

    def project(self, points: jnp.ndarray, *, backend: str | None = None,
                dtype: str | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Apply the chain and return ``(projected (..., d), inside (...,)
        bool)`` -- the perspective-divided points plus the frustum-cull
        mask, still ONE fused kernel launch (the divide, the cull test,
        and the per-point mask reduction all happen in-kernel).  Affine
        chains project trivially: same result as ``apply``, mask all
        True.  ``dtype`` (the fixed-point lane) is affine-only, exactly
        as in ``apply``: a projective chain + dtype is rejected (by the
        delegated ``apply`` -- one intake rule, one spelling)."""
        if dtype is not None:
            return (self.apply(points, backend=backend, dtype=dtype),
                    jnp.ones(points.shape[:-1], bool))
        errors.check_points(points, self.dim)
        d = points.shape[-1]
        if not self.is_projective:
            return (self.apply(points, backend=backend),
                    jnp.ones(points.shape[:-1], bool))
        if _params_traced(self.params):
            raise NotImplementedError(
                "projective chains require concrete parameters (the "
                "homogeneous fold runs host-side)")
        flat = points.reshape(-1, d)
        plan = self._plan(backend)
        self._record_fused(plan, flat, d)
        out, mask = plan.fn(self.fold(), flat)
        return out.reshape(points.shape), mask.reshape(points.shape[:-1])

    def apply_many(self, points: jnp.ndarray, *, backend: str | None = None,
                   dtype: str | None = None) -> jnp.ndarray:
        """Map one compiled plan over a leading batch axis: (B, ..., d) in,
        (B, ..., d) out, still a single fused kernel launch (the batch is
        part of the flattened point buffer, not a loop of launches)."""
        if points.ndim < 3:
            raise ValueError("apply_many expects (B, ..., d) with ndim >= 3")
        return self.apply(points, backend=backend, dtype=dtype)
