"""Fused transform-chain compiler: the paper's one-pass composite, lazily.

The paper's "General Composite Algorithm using Matrix Algorithm" collapses
an arbitrary translate/scale/rotate pipeline into a single pass over the RC
array.  ``TransformChain`` is that idea as a small compiler:

  1. **IR** -- builder calls (``translate``/``scale``/``rotate``/``affine``/
     ``matrix``, 2D and 3D homogeneous) record primitives only; no jnp work
     happens until ``apply``, so composing a chain is allocation-free.
  2. **Fold** -- the recorded chain folds algebraically: adjacent translates
     sum, scales multiply, scale+translate fuse into one affine (s, t), and
     anything containing a rotation or a custom matrix folds into a single
     composed (A, t) pair.  Chains whose structure is pure-diagonal
     (translate/scale/affine only) never build a matrix and never touch the
     MXU.  The fold itself is O(k d^2) scalar work and runs host-side in
     numpy -- one shared code path for single-request ``apply`` and the
     serving engine, so a request folds to bit-identical composed
     parameters however it is dispatched (see the folding section note).
  3. **Lower** -- the folded chain lowers to ONE fused lane-dense Pallas
     kernel over the flattened point buffer -- one HBM read of the points,
     one write, with the composed parameters staged as (1, w) context-word
     rows: ``kernels.chain_diag`` for diagonal plans, ``kernels.chain_apply``
     (2d-1 lane-rolled multiply-adds) for general plans.
  4. **Plan cache** -- compiled plans are cached by *chain structure* +
     backend, and the jitted plan function takes the folded parameter
     values as arguments, so the serving hot path (same chain shape, fresh
     parameter values every request) recompiles and retraces nothing.

Byte economy vs. sequential primitive dispatch (k-long chain over N points
of dim d, itemsize 4): sequential moves ~2*k*N*d*4 bytes HBM<->VMEM; the
fused plan moves 2*N*d*4 + O(1).  ``kernels.opcount`` makes this testable.

``repro.serving.GeometryServer`` layers plan-bucketed batched serving on
top of this compiler: same-structure requests pack into one (B, L, d)
batch and execute through the batched forms of the same chain kernels --
one launch per bucket instead of one per request.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune import cache as tuning
from repro.kernels import dispatch, opcount
from repro.kernels.affine import chain_diag as _k_chain_diag
from repro.kernels.matmul import chain_apply as _k_chain_apply

# primitive kinds: T translate, S scale, R rotate, A affine(s, t), M matrix
_DIAG_KINDS = frozenset("TSA")
_AXES = {"x": 0, "y": 1, "z": 2}

#: plan-cache / trace statistics (observable by tests and benchmarks):
#:   compiles -- plans built (structure-level cache misses)
#:   hits     -- plans served from the cache
#:   traces   -- executions of a plan body under jax tracing (a cached plan
#:               applied at a seen shape/dtype must not bump this)
stats = {"compiles": 0, "hits": 0, "traces": 0}

_PLAN_CACHE: dict[tuple, "Plan"] = {}


def clear_plan_cache() -> None:
    """Drop all compiled plans (benchmarks use this to measure cold cost)."""
    _PLAN_CACHE.clear()


def reset_stats() -> None:
    for k in stats:
        stats[k] = 0


# -- folding (host-side numpy float32) ---------------------------------------
#
# The fold runs on the host, in numpy, NOT inside the jitted plan.  That is
# a determinism decision, not a performance one (either way it is O(k d^2)
# scalar work): XLA:CPU takes per-program freedom in fusing float
# multiply-adds (FMA contraction, operand association -- both observed, and
# neither controllable: optimization barriers and bitcast fences are folded
# away by the algebraic simplifier), so the "same" fold traced at two
# different batch shapes can differ in its last ULP.  One shared host fold
# means a request folds to *bit-identical* composed parameters whether it
# is applied alone or packed into a serving bucket, leaving the fused
# kernel application as the only XLA-shaped code -- see
# ``serving.engine`` for the resulting equality contract.

def _vec(v, dim: int) -> np.ndarray:
    v = np.asarray(v, np.float32)
    if v.ndim == 0:
        v = np.broadcast_to(v, (dim,))
    return v.reshape(dim)


def _rot(dim: int, axis: int, theta) -> np.ndarray:
    """Right-multiply (row-vector) rotation matrix: q = p @ R."""
    c = np.cos(np.float32(theta), dtype=np.float32)
    s = np.sin(np.float32(theta), dtype=np.float32)
    if dim == 2:
        return np.array([[c, s], [-s, c]], np.float32)
    r = np.eye(3, dtype=np.float32)
    i, j = [(1, 2), (2, 0), (0, 1)][axis]   # rotation plane for axis x/y/z
    r[i, i] = r[j, j] = c
    r[i, j], r[j, i] = s, -s
    return r


def _mat_parts(val, dim: int) -> tuple[np.ndarray, np.ndarray]:
    """Split a custom-matrix param into (A (d,d), t (d,)); accepts a (d, d)
    linear matrix or a (d+1, d+1) homogeneous one (row-vector convention)."""
    m = np.asarray(val, np.float32)
    if m.shape == (dim + 1, dim + 1):
        return m[:dim, :dim], m[dim, :dim]
    if m.shape == (dim, dim):
        return m, np.zeros((dim,), np.float32)
    raise ValueError(f"matrix must be ({dim},{dim}) or "
                     f"({dim + 1},{dim + 1}); got {m.shape}")


def _fold_diag(dim: int, kinds, params) -> tuple[np.ndarray, np.ndarray]:
    """Fold a pure-diagonal chain to (s, t) with q = s (.) p + t."""
    s = np.ones((dim,), np.float32)
    t = np.zeros((dim,), np.float32)
    for (kind, _), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec(val, dim)
        elif kind == "S":
            v = _vec(val, dim)
            s, t = s * v, t * v
        else:                                   # "A": y = v*y + u
            v, u = _vec(val[0], dim), _vec(val[1], dim)
            s, t = s * v, t * v + u
    return s, t


def _fold_matrix(dim: int, kinds, params) -> tuple[np.ndarray, np.ndarray]:
    """Fold a general chain to (A, t) with q = p @ A + t."""
    a = np.eye(dim, dtype=np.float32)
    t = np.zeros((dim,), np.float32)
    for (kind, axis), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec(val, dim)
        elif kind == "S":
            v = _vec(val, dim)
            a, t = a * v[None, :], t * v
        elif kind == "A":
            v, u = _vec(val[0], dim), _vec(val[1], dim)
            a, t = a * v[None, :], t * v + u
        elif kind == "R":
            r = _rot(dim, axis, val)
            a, t = a @ r, t @ r
        else:                                   # "M"
            m, u = _mat_parts(val, dim)
            a, t = a @ m, t @ m + u
    return a, t


# -- traced-parameter fallback (jnp fold) ------------------------------------
#
# The host fold requires concrete parameter values.  When a caller traces
# chain *parameters* -- jax.grad over a rotation angle, jit over a pose --
# ``apply`` instead folds in jnp inside the caller's own trace and calls
# the fused kernel entry directly (plan caching is moot there: the caller's
# jit already owns compilation).  This path is differentiable; it is NOT
# part of the serving bit-identity contract, which is about concrete
# parameters.

def _vec_jnp(v, dim: int):
    v = jnp.asarray(v, jnp.float32)
    return jnp.broadcast_to(v, (dim,)) if v.ndim == 0 else v.reshape(dim)


def _rot_jnp(dim: int, axis: int, theta):
    c = jnp.cos(jnp.asarray(theta, jnp.float32))
    s = jnp.sin(jnp.asarray(theta, jnp.float32))
    if dim == 2:
        return jnp.eye(2, dtype=jnp.float32) * c + \
            jnp.array([[0.0, 1.0], [-1.0, 0.0]], jnp.float32) * s
    i, j = [(1, 2), (2, 0), (0, 1)][axis]
    r = jnp.eye(3, dtype=jnp.float32).at[i, i].set(c).at[j, j].set(c)
    return r.at[i, j].set(s).at[j, i].set(-s)


def _fold_jnp(dim: int, kinds, params):
    a = jnp.eye(dim, dtype=jnp.float32)
    t = jnp.zeros((dim,), jnp.float32)
    for (kind, axis), val in zip(kinds, params):
        if kind == "T":
            t = t + _vec_jnp(val, dim)
        elif kind == "S":
            v = _vec_jnp(val, dim)
            a, t = a * v[None, :], t * v
        elif kind == "A":
            v, u = _vec_jnp(val[0], dim), _vec_jnp(val[1], dim)
            a, t = a * v[None, :], t * v + u
        elif kind == "R":
            r = _rot_jnp(dim, axis, val)
            a, t = a @ r, t @ r
        else:                                   # "M"
            m = jnp.asarray(val, jnp.float32)
            if m.shape == (dim + 1, dim + 1):
                m, u = m[:dim, :dim], m[dim, :dim]
            else:
                u = jnp.zeros((dim,), jnp.float32)
            a, t = a @ m, t @ m + u
    return a, t


def _params_traced(params) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves(params))


def structure_is_diagonal(structure: tuple) -> bool:
    """True if ``structure`` (a ``TransformChain.structure`` value) folds to
    a diagonal (s, t) plan -- translate/scale/affine primitives only."""
    _, kinds = structure
    return all(k in _DIAG_KINDS for k, _ in kinds)


def fold_structure(structure: tuple, params) -> tuple[np.ndarray, np.ndarray]:
    """Fold ONE parameter set for ``structure``: float32 (s, t) if the
    structure is diagonal, else (A, t).  This host fold is shared verbatim
    by ``TransformChain.apply`` and the serving engine's bucket packing, so
    a request's composed parameters are bit-identical however it is
    dispatched."""
    dim, kinds = structure
    if structure_is_diagonal(structure):
        return _fold_diag(dim, kinds, params)
    return _fold_matrix(dim, kinds, params)


# -- plans -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Plan:
    """A compiled chain: ``fn(folded, flat_points_2d) -> out`` (jitted),
    where ``folded`` is the host-folded (s, t) or (A, t) pair."""
    kind: str                      # "diag" | "matrix"
    dim: int
    backend: str
    length: int                    # primitives folded into this plan
    fn: typing.Callable


def _compile(structure: tuple, backend: str) -> Plan:
    dim, kinds = structure
    diagonal = structure_is_diagonal(structure)

    # The tuning-cache consult happens inside the plan body, i.e. at
    # TRACE time: point shapes are concrete there, so the lookup keys on
    # the actual size class, and a cached plan applied at a seen shape
    # re-consults nothing (the config is baked into the trace, which is
    # why ``repro.autotune.set_enabled`` clears the plan cache).  With
    # tuning disabled this returns the deterministic defaults; any config
    # is bit-identical (staging-only knobs), so tuned and untuned plans
    # agree bitwise.
    if diagonal:
        def body(folded, pts2):
            stats["traces"] += 1
            s, t = folded
            cfg = tuning.config_for("chain_diag", backend,
                                    str(pts2.dtype), pts2.shape[0])
            return _k_chain_diag(pts2, s, t, backend=backend, config=cfg)
    else:
        def body(folded, pts2):
            stats["traces"] += 1
            a, t = folded
            cfg = tuning.config_for("chain_apply", backend,
                                    str(pts2.dtype), pts2.shape[0])
            return _k_chain_apply(pts2, a, t, backend=backend, config=cfg)

    return Plan(kind="diag" if diagonal else "matrix", dim=dim,
                backend=backend, length=len(kinds), fn=jax.jit(body))


def _get_plan(structure: tuple, backend: str) -> Plan:
    key = (structure, backend)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        stats["compiles"] += 1
        plan = _compile(structure, backend)
        _PLAN_CACHE[key] = plan
    else:
        stats["hits"] += 1
    return plan


# -- the chain IR ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformChain:
    """Lazy composite-transform IR.  Builder methods append primitives
    without any jnp work; ``apply`` folds + lowers through the plan cache.

        chain = (TransformChain.identity(dim=2)
                 .scale(2.0, 0.5).rotate(0.3).translate(1.0, -2.0))
        q = chain.apply(points)            # one fused kernel launch
    """
    dim: int
    kinds: tuple = ()              # ((kind, axis), ...) -- the structure
    params: tuple = ()             # raw per-primitive parameter values

    @staticmethod
    def identity(dim: int = 2) -> "TransformChain":
        if dim not in (2, 3):
            raise ValueError(f"dim must be 2 or 3, got {dim}")
        return TransformChain(dim=dim)

    def _push(self, kind: str, axis: int, param) -> "TransformChain":
        return TransformChain(self.dim, self.kinds + ((kind, axis),),
                              self.params + (param,))

    def _vec_arg(self, name: str, args):
        if len(args) == 1:
            return args[0]
        if len(args) != self.dim:
            raise ValueError(f"{name} takes 1 or {self.dim} components, "
                             f"got {len(args)}")
        return tuple(args)

    def translate(self, *t) -> "TransformChain":
        """Append q = p + t (scalar broadcast or one component per dim)."""
        return self._push("T", -1, self._vec_arg("translate", t))

    def scale(self, *s) -> "TransformChain":
        """Append q = s (.) p (scalar or per-dim factors)."""
        return self._push("S", -1, self._vec_arg("scale", s))

    def rotate(self, theta, axis=None) -> "TransformChain":
        """Append a rotation by ``theta`` (radians).  3D chains name the
        axis (0/1/2 or "x"/"y"/"z"); 2D chains take none."""
        if self.dim == 2:
            if axis is not None:
                raise ValueError("2D rotations take no axis")
            return self._push("R", -1, theta)
        if axis is None:
            raise ValueError("3D rotations need axis= (0/1/2 or x/y/z)")
        ax = _AXES.get(axis, axis)
        if ax not in (0, 1, 2):
            raise ValueError(f"bad rotation axis {axis!r}")
        return self._push("R", ax, theta)

    def affine(self, s, t) -> "TransformChain":
        """Append the fused q = s (.) p + t (scalars or per-dim vectors)."""
        return self._push("A", -1, (s, t))

    def matrix(self, m) -> "TransformChain":
        """Append a custom (d, d) linear or (d+1, d+1) homogeneous matrix
        (row-vector convention: q = [p, 1] @ M)."""
        return self._push("M", -1, m)

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        return len(self.kinds)

    @property
    def structure(self) -> tuple:
        """Hashable plan-cache key component: dims + primitive kinds/axes
        (parameter *values* are plan operands, not part of the key)."""
        return (self.dim, self.kinds)

    @property
    def is_diagonal(self) -> bool:
        """True if the folded chain is diagonal (never touches the MXU)."""
        return all(k in _DIAG_KINDS for k, _ in self.kinds)

    @property
    def plan_kind(self) -> str:
        """The plan class this structure lowers to: "diag" (VPU-only
        fused affine) or "matrix" (lane-rolled q = p @ A + t)."""
        return "diag" if self.is_diagonal else "matrix"

    def fold(self) -> tuple[np.ndarray, np.ndarray]:
        """The host fold this chain's plan consumes: float32 (s, t) for
        diagonal structures, (A, t) otherwise.  Bit-identical wherever it is
        computed -- ``apply``, the serving engine, a test -- because it is
        one shared numpy code path (see the folding section note)."""
        return fold_structure(self.structure, self.params)

    def folded(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Eagerly fold to the composed (A (d,d), t (d,)) pair."""
        if _params_traced(self.params):
            return _fold_jnp(self.dim, self.kinds, self.params)
        if self.is_diagonal:
            s, t = self.fold()
            return jnp.asarray(np.diag(s)), jnp.asarray(t)
        a, t = self.fold()
        return jnp.asarray(a), jnp.asarray(t)

    def as_homogeneous(self) -> jnp.ndarray:
        """The composed (d+1, d+1) homogeneous matrix (row-vector form)."""
        a, t = self.folded()
        d = self.dim
        h = jnp.zeros((d + 1, d + 1), jnp.float32)
        h = h.at[:d, :d].set(a).at[d, :d].set(t).at[d, d].set(1.0)
        return h

    # -- execution -----------------------------------------------------------

    def _plan(self, backend: str | None) -> Plan:
        return _get_plan(self.structure, dispatch.resolve(backend))

    def apply(self, points: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
        """Apply the folded chain to (..., d) points in one fused pass.

        Concrete parameters go through the cached plan (host fold, see the
        folding section note); parameters that are jax tracers fold in jnp
        inside the caller's trace instead, so grad/jit over chain
        parameters (pose optimisation) stays differentiable."""
        d = points.shape[-1]
        if d != self.dim:
            raise ValueError(f"chain is {self.dim}D, points are (..., {d})")
        if not self.kinds:
            return points
        flat = points.reshape(-1, d)
        if _params_traced(self.params):
            # chain parameters are jax tracers (grad/jit over a pose):
            # fold in jnp inside the caller's trace, differentiably
            opcount.record("chain_fused_traced",
                           2 * flat.nbytes + 4 * (d * d + d))
            a, t = _fold_jnp(d, self.kinds, self.params)
            out = _k_chain_apply(flat, a, t, backend=backend)
            return out.reshape(points.shape)
        plan = self._plan(backend)
        # composed-parameter words: (A, t) for matrix plans, (s, t) for
        # diagonal -- the same accounting costmodel.chain_cost predicts
        param_bytes = 4 * (d * d + d if plan.kind == "matrix" else 2 * d)
        opcount.record(f"chain_fused_{plan.kind}",
                       2 * flat.nbytes + param_bytes)
        out = plan.fn(self.fold(), flat)
        return out.reshape(points.shape)

    def apply_many(self, points: jnp.ndarray, *, backend: str | None = None) -> jnp.ndarray:
        """Map one compiled plan over a leading batch axis: (B, ..., d) in,
        (B, ..., d) out, still a single fused kernel launch (the batch is
        part of the flattened point buffer, not a loop of launches)."""
        if points.ndim < 3:
            raise ValueError("apply_many expects (B, ..., d) with ndim >= 3")
        return self.apply(points, backend=backend)
