"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

    compute    = device_flops / peak_flops
    memory     = device_bytes / hbm_bw
    collective = device_collective_bytes / link_bw

``cost_analysis`` on an SPMD-partitioned executable reports *per-device*
FLOPs/bytes, and collective operand shapes in the partitioned HLO are
per-device too, so each term divides by a single chip's peak -- equivalent
to the global-totals/(chips x peak) formulation.

Collective bytes are NOT in cost_analysis: we parse the optimized HLO and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async *-start counted once, *-done skipped).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_LINK_BW = 50e9         # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# shape like bf16[2,1024,8192]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "  %name = TYPE[...] opcode(...)," -- capture opcode and the operand text
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device operand bytes of every collective in optimized HLO."""
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVES:
            continue
        # operand shapes: everything after the opcode's opening paren
        idx = line.find(op)
        paren = line.find("(", idx)
        operand_text = line[paren:line.rfind(")")]
        shapes = _SHAPE_RE.findall(operand_text)
        if not shapes:  # operands printed without types: fall back to output
            shapes = _SHAPE_RE.findall(line[:line.find("=")]) or \
                _SHAPE_RE.findall(line)
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        bytes_by[op] += total
        count_by[op] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device
    hbm_bytes: float           # per-device
    collective_bytes: float    # per-device
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


def roofline_terms(cost: dict, collectives: CollectiveStats) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = float(collectives.total_bytes)
    tc = flops / PEAK_FLOPS
    tm = hbm / HBM_BW
    tx = coll / ICI_LINK_BW
    terms = {"compute": tc, "memory": tm, "collective": tx}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops, hbm, coll, tc, tm, tx, bottleneck)


def model_flops_utilization(model_flops_per_device: float,
                            roof: Roofline) -> dict:
    """MODEL_FLOPS/HLO_FLOPs and the roofline fraction of the dominant term."""
    useful = (model_flops_per_device / roof.flops) if roof.flops else 0.0
    # fraction of roofline: time the useful compute would take at peak over
    # the dominant-term time (how close the cell is to its own roofline)
    t_useful = model_flops_per_device / PEAK_FLOPS
    frac = t_useful / roof.t_bound if roof.t_bound else 0.0
    return {"useful_flops_ratio": useful, "roofline_fraction": frac}
