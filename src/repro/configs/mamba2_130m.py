"""mamba2-130m [ssm]: SSD (state-space duality), attention-free
[arXiv:2405.21060].  Recurrent state only -> runs long_500k."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        tie_embeddings=True,
        source="arXiv:2405.21060; unverified",
    )
