"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer
[arXiv:2411.13676].  SWA on the attention path (window 1024) + SSM state 16;
both state streams are bounded, so this arch runs long_500k."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        window=1024,
        ssm_state=16,
        ssm_headdim=64,
        tie_embeddings=True,
        source="arXiv:2411.13676; hf",
    )
