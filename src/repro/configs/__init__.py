"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = {
    "internvl2-76b": "internvl2_76b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "dbrx-132b": "dbrx_132b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "deepseek-67b": "deepseek_67b",
    "yi-6b": "yi_6b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-medium": "whisper_medium",
    "mamba2-130m": "mamba2_130m",
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.config()


def list_archs() -> list[str]:
    return list(ARCHS)
