"""internvl2-76b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone-only per assignment: the vision frontend is a stub supplying
precomputed patch embeddings for the first 256 positions."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        frontend="vision",
        remat="full",
        n_frontend_tokens=256,
        source="arXiv:2404.16821; unverified",
    )
