"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention
[arXiv:2401.16818].  The 4096-token window bounds the KV cache, so this arch
runs the long_500k cell."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        window=4096,
        source="arXiv:2401.16818; hf",
    )
