"""whisper-medium [audio]: enc-dec, conv frontend stubbed to precomputed
frame embeddings per assignment [arXiv:2212.04356].  24 encoder + 24 decoder
layers, sinusoidal positions, MHA (kv=16)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        n_layers=24,
        encoder_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        pos_embed="sinusoidal",
        frontend="audio",
        tie_embeddings=True,
        source="arXiv:2212.04356; unverified",
    )
