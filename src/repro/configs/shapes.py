"""Assigned input-shape set + per-(arch x shape) applicability.

LM transformer shapes are seq_len x global_batch.  decode_*/long_* lower
``serve_step`` (one new token against a seq_len KV cache), not train_step.
long_500k needs sub-quadratic state: it runs only for archs whose per-token
state is bounded (SSM / hybrid / SWA); skips are recorded with reasons
(DESIGN.md section Arch-applicability)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicability(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape != "long_500k":
        return True, ""
    if cfg.family in ("ssm", "hybrid"):
        return True, ""
    if cfg.is_encdec:
        return False, "enc-dec audio backbone: no 500k decode stream"
    if cfg.window is not None:
        return True, ""                      # SWA bounds the cache
    return False, "pure full attention: unbounded 500k KV cache (assignment: skip)"


def cells(archs: list[str]) -> list[tuple[str, str, bool, str]]:
    """All 40 (arch, shape) cells with applicability flags."""
    from repro import configs
    out = []
    for a in archs:
        cfg = configs.get(a)
        for s in SHAPES:
            ok, why = applicability(cfg, s)
            out.append((a, s, ok, why))
    return out


# training knobs per arch: sequences per data shard per microbatch
# (gradient accumulation covers the rest of the global batch)
MICROBATCH_PER_SHARD = {
    "internvl2-76b": 1,
    "deepseek-67b": 1,
    "dbrx-132b": 1,
    "yi-6b": 2,
    "phi3-mini-3.8b": 2,
    "whisper-medium": 2,
    "granite-moe-3b-a800m": 1,
    "h2o-danube-1.8b": 4,
    "hymba-1.5b": 2,
    "mamba2-130m": 2,
}
