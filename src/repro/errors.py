"""Typed request-validation errors: the serving fault taxonomy's base layer.

One malformed request -- NaN points, a float64 buffer, an empty point
set, a q-format that would wrap -- used to detonate *later*, inside a
packed bucket, where the failure poisons every co-batched request in the
flush.  These exceptions move the failure to the intake boundary and
give it a machine-readable shape: every class carries a stable ``code``
(the error-taxonomy key CI counters and logs group by) and the offending
``ticket`` (request id) when one exists, and every class subclasses
``ValueError`` so existing ``except ValueError`` call sites keep
working.

Layering: this module depends on numpy only.  ``repro.core`` (the chain
compiler) and ``repro.serving`` (the engine) both raise these, which is
why they live here rather than inside either package --
``repro.serving.errors`` re-exports the taxonomy and adds the
serving-only members (``LaunchError``, ``InjectedFault``).

See ``docs/architecture.md`` section 6 for the full fault model.
"""
from __future__ import annotations

import math

import numpy as np


class RequestError(ValueError):
    """Base of the typed request-error taxonomy.

    ``code`` is the stable taxonomy key ("shape", "dtype", "empty",
    "nonfinite", "q-range", "launch"); ``ticket`` is the serving request
    id when the error is tied to one (None at the library boundary,
    e.g. ``TransformChain.apply``).  A ``RequestError`` is also how a
    request *resolves* when recovery is exhausted: ``GeometryServer.
    flush`` returns the error object in the request's result slot
    instead of losing the co-batched requests around it.
    """
    code = "request"

    def __init__(self, message: str, *, ticket: int | None = None):
        self.message = message
        self.ticket = ticket
        prefix = f"[request {ticket}] " if ticket is not None else ""
        super().__init__(f"{prefix}{message}")

    def with_ticket(self, ticket: int) -> "RequestError":
        """The same error re-raised with the offending request id."""
        return type(self)(self.message, ticket=ticket)


class ShapeError(RequestError):
    """Points whose shape cannot mean anything for the chain: wrong last
    dimension, or a bare scalar."""
    code = "shape"


class DtypeError(RequestError, TypeError):
    """Points in a dtype the lane does not execute (float64 is rejected
    rather than silently narrowed; the serving boundary is strict
    float32 / int16).  Also a ``TypeError``: dtype misuse historically
    raised that, and both spellings must keep catching it."""
    code = "dtype"


class EmptyPointsError(RequestError):
    """A zero-point request: silently accepted before, now rejected at
    the boundary (an empty launch wastes a bucket slot and an empty
    result is indistinguishable from a lost one)."""
    code = "empty"


class NonFiniteError(RequestError):
    """NaN/Inf in the submitted points, or chain parameters that fold to
    non-finite composed values -- either would poison every co-batched
    request's kernel launch."""
    code = "nonfinite"


class QRangeError(RequestError):
    """The fixed-point error bound predicts int16 wrap-around for this
    request's folded parameters and input range (``quantize.fits`` is
    False).  Raised when the overflow policy is "reject"; the
    "fallback" policy reroutes to the float32 lane instead."""
    code = "q-range"


class LaunchError(RequestError):
    """A kernel launch kept failing after the full recovery ladder --
    retries with backoff, backend degradation, and bisection down to a
    single request.  This is the terminal per-request resolution: it
    occupies the request's result slot so sibling requests are never
    lost with it."""
    code = "launch"


def check_points(points, dim: int, *, ticket: int | None = None) -> None:
    """The shared boundary check of ``TransformChain.apply`` and
    ``GeometryServer.submit``: points must be (..., dim)-shaped,
    non-empty, and not float64 (use float32 -- silently narrowing 8-byte
    words would halve precision without the caller asking).  Works on
    numpy arrays, jax arrays, and tracers (shape/dtype are static);
    finiteness is the *serving* boundary's extra check
    (``GeometryServer.submit``), not done here -- it would force a
    device sync on the apply hot path.
    """
    shape = getattr(points, "shape", None)
    if shape is None or len(shape) < 1 or shape[-1] != dim:
        raise ShapeError(
            f"chain is {dim}D, points are {shape}", ticket=ticket)
    if math.prod(shape) == 0:
        raise EmptyPointsError(
            f"empty point set {shape}: zero-point requests are rejected at "
            "the boundary (an empty result is indistinguishable from a "
            "lost one)", ticket=ticket)
    if np.dtype(getattr(points, "dtype", np.float32)) == np.float64:
        raise DtypeError(
            "float64 points are not executed (the lanes are float32 / "
            "int16 Qm.n); convert with .astype(np.float32)", ticket=ticket)
